"""Pure-jnp oracles for every Pallas kernel (self-contained, no Pallas).

These are the correctness references the kernel sweep tests
``assert_allclose`` against.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# flash attention (prefill)
# ---------------------------------------------------------------------------


def flash_attention_ref(q, k, v, causal: bool = True):
    """q: [B,H,Sq,D]; k/v: [B,Hkv,Sk,D] (GQA by head grouping)."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    qg = q.reshape(b, hkv, g, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), sk - sq)
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v)
    return out.reshape(b, h, sq, d)


# ---------------------------------------------------------------------------
# paged attention (decode)
# ---------------------------------------------------------------------------


def paged_attention_ref(q, k_pages, v_pages, page_table, lengths):
    """Decode attention over a paged KV cache.

    q: [B, H, D] (one new token per sequence)
    k_pages/v_pages: [n_pages, page, Hkv, D]  (the "flash" pool)
    page_table: [B, pages_per_seq] int32 physical page ids
    lengths: [B] int32 valid token counts
    Returns [B, H, D].
    """
    b, h, d = q.shape
    n_pages, page, hkv, _ = k_pages.shape
    pages_per_seq = page_table.shape[1]
    g = h // hkv
    # gather logical KV [B, S, Hkv, D]
    k = k_pages[page_table].reshape(b, pages_per_seq * page, hkv, d)
    v = v_pages[page_table].reshape(b, pages_per_seq * page, hkv, d)
    qg = q.reshape(b, hkv, g, d)
    logits = jnp.einsum("bhgd,bshd->bhgs", qg, k,
                        preferred_element_type=jnp.float32) / math.sqrt(d)
    valid = jnp.arange(pages_per_seq * page)[None] < lengths[:, None]
    logits = jnp.where(valid[:, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", probs.astype(q.dtype), v)
    return out.reshape(b, h, d)


def paged_attention_q8_ref(q, k_pages, v_pages, k_scale, v_scale,
                           page_table, lengths):
    """Oracle for the int8-KV paged kernel: dequantize, then the fp ref."""
    kd = k_pages.astype(jnp.float32) * k_scale[..., None]
    vd = v_pages.astype(jnp.float32) * v_scale[..., None]
    return paged_attention_ref(q, kd.astype(q.dtype), vd.astype(q.dtype),
                               page_table, lengths)


# ---------------------------------------------------------------------------
# embedding-bag aggregation (DLRM 'embed' workload)
# ---------------------------------------------------------------------------


def embed_agg_ref(table, indices, weights=None):
    """Sum-pool embedding lookups (sparse-feature aggregation).

    table: [V, D]; indices: [B, L] int32; weights: optional [B, L].
    Returns [B, D] = sum_l w[b,l] * table[indices[b,l]].
    """
    rows = table[indices]                        # [B, L, D]
    if weights is not None:
        rows = rows * weights[..., None].astype(rows.dtype)
    return jnp.sum(rows, axis=1)


# ---------------------------------------------------------------------------
# paged scan/filter/reduce (in-storage analytics)
# ---------------------------------------------------------------------------


def scan_filter_reduce_ref(data, page_rows: int, threshold=0.0, *,
                           filter_col: int = 0, filter_op: str = "all"):
    """Host-side reference for ``kernels.isp_scan.scan_filter_reduce``.

    data: [n_rows, n_cols] — the extent the host read back in full
    (the "host reads everything" baseline).  The fold walks pages of
    ``page_rows`` rows sequentially with the *same* float32 ops and
    order as the kernel, so the result is bit-identical to the
    in-storage path — the offload correctness contract.
    Returns [8, n_cols] float32 (count/sum/min/max rows, zero padding).
    """
    from repro.kernels.isp_scan import (NEG_INF, POS_INF, REDUCE_ROWS,
                                        _predicate)
    n_rows, n_cols = data.shape
    n_pages = -(-max(n_rows, 1) // page_rows)
    pad = n_pages * page_rows - n_rows
    blocks = jnp.pad(data.astype(jnp.float32), ((0, pad), (0, 0))
                     ).reshape(n_pages, page_rows, n_cols)
    thresh = jnp.asarray(threshold, jnp.float32).reshape(())

    def fold(carry, xs):
        cnt, s, mn, mx = carry
        pi, block = xs
        pos = pi * page_rows + jnp.arange(page_rows, dtype=jnp.int32)[:, None]
        key = block[:, filter_col:filter_col + 1]
        mask = (pos < n_rows) & _predicate(key, thresh, filter_op)
        cnt = cnt + jnp.sum(mask.astype(jnp.float32))
        s = s + jnp.sum(jnp.where(mask, block, 0.0), axis=0)
        mn = jnp.minimum(mn, jnp.min(jnp.where(mask, block, POS_INF), axis=0))
        mx = jnp.maximum(mx, jnp.max(jnp.where(mask, block, NEG_INF), axis=0))
        return (cnt, s, mn, mx), None

    init = (jnp.zeros((), jnp.float32),
            jnp.zeros((n_cols,), jnp.float32),
            jnp.full((n_cols,), POS_INF, jnp.float32),
            jnp.full((n_cols,), NEG_INF, jnp.float32))
    (cnt, s, mn, mx), _ = lax.scan(
        fold, init, (jnp.arange(n_pages, dtype=jnp.int32), blocks))
    out = jnp.zeros((REDUCE_ROWS, n_cols), jnp.float32)
    return out.at[0].set(cnt).at[1].set(s).at[2].set(mn).at[3].set(mx)


def topk_scan_ref(data, query, *, page_rows: int, k: int,
                  metric: str = "dot"):
    """Host-side reference for ``kernels.isp_scan.topk_scan``.

    data: [n_rows, n_cols] — the extent the host read back in full.
    The fold walks pages sequentially and calls the kernel's *own*
    page fold (``_topk_fold_page``), so scores, merge order, and
    tie-breaking (smallest row id wins equal scores) are bit-identical
    to the in-storage path by construction.
    Returns [8, topk_pad(k)] float32 (scores row 0, f32 ids row 1).
    """
    from repro.kernels.isp_scan import (BIG_ID, NEG_INF, REDUCE_ROWS,
                                        _topk_fold_page, topk_pad)
    n_rows, n_cols = data.shape
    n_pages = -(-max(n_rows, 1) // page_rows)
    pad = n_pages * page_rows - n_rows
    blocks = jnp.pad(data.astype(jnp.float32), ((0, pad), (0, 0))
                     ).reshape(n_pages, page_rows, n_cols)
    q = jnp.asarray(query, jnp.float32).reshape(1, n_cols)

    def fold(carry, xs):
        acc_s, acc_i = carry
        pi, block = xs
        return _topk_fold_page(block, pi, n_rows, q, acc_s, acc_i,
                               page_rows=page_rows, k=k,
                               metric=metric), None

    init = (jnp.full((1, k), NEG_INF, jnp.float32),
            jnp.full((1, k), BIG_ID, jnp.float32))
    (acc_s, acc_i), _ = lax.scan(
        fold, init, (jnp.arange(n_pages, dtype=jnp.int32), blocks))
    out = jnp.zeros((REDUCE_ROWS, topk_pad(k)), jnp.float32)
    return out.at[0, :k].set(acc_s[0]).at[1, :k].set(acc_i[0])


# ---------------------------------------------------------------------------
# rwkv6 wkv chunked recurrence
# ---------------------------------------------------------------------------


def wkv_ref(r, k, v, logw, u, s0):
    """Naive per-token scan.  r/k/logw: [B,S,H,dk]; v: [B,S,H,dv];
    u: [H,dk]; s0: [B,H,dk,dv].  Returns (o [B,S,H,dv], sT)."""
    def step(state, xs):
        rt, kt, vt, wt = xs
        kv = kt[..., :, None] * vt[..., None, :]
        o = jnp.einsum("bhk,bhkv->bhv", rt, state + u[None, :, :, None] * kv)
        state = jnp.exp(wt)[..., None] * state + kv
        return state, o
    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, logw))
    sT, os = lax.scan(step, s0, xs)
    return jnp.moveaxis(os, 0, 1), sT
