"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True``;
on TPU they compile natively.  ``ref.py`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (re-exported oracle module)
from repro.kernels.embed_agg import embed_agg as _embed_agg
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.paged_attention import (paged_attention as _paged,
                                            paged_attention_q8 as _paged_q8)
from repro.kernels.rwkv_scan import rwkv_scan as _rwkv


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, lengths,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _paged(q, k_pages, v_pages, page_table, lengths,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_q8(q, k_pages, v_pages, k_scale, v_scale, page_table,
                       lengths, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _paged_q8(q, k_pages, v_pages, k_scale, v_scale, page_table,
                     lengths, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def embed_agg(table, indices, weights=None, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _embed_agg(table, indices, weights, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan(r, k, v, logw, u, s0, chunk: int = 32,
              interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _rwkv(r, k, v, logw, u, s0, chunk=chunk, interpret=interpret)
