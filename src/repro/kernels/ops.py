"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) the kernels execute under ``interpret=True``;
on TPU they compile natively.  ``ref.py`` holds the pure-jnp oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (re-exported oracle module)
from repro.kernels.embed_agg import (embed_agg as _embed_agg,
                                     embed_gather as _embed_gather,
                                     validate_embed_args)
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.isp_scan import (scan_filter_reduce as _scan_reduce,
                                    topk_scan as _topk_scan,
                                    REDUCE_ROWS, topk_pad)  # noqa: F401
from repro.kernels.paged_attention import (paged_attention as _paged,
                                            paged_attention_q8 as _paged_q8)
from repro.kernels.rwkv_scan import rwkv_scan as _rwkv


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _flash(q, k, v, causal=causal, block_q=block_q, block_k=block_k,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q, k_pages, v_pages, page_table, lengths,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _paged(q, k_pages, v_pages, page_table, lengths,
                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_q8(q, k_pages, v_pages, k_scale, v_scale, page_table,
                       lengths, interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _paged_q8(q, k_pages, v_pages, k_scale, v_scale, page_table,
                     lengths, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _embed_agg_jit(table, indices, weights, interpret: bool):
    return _embed_agg(table, indices, weights, interpret=interpret)


def embed_agg(table, indices, weights=None, interpret: bool | None = None):
    """Validating wrapper: dtype/bounds are checked *eagerly* (out-of-range
    vocab ids raise instead of silently clamping), then the jitted kernel
    runs."""
    if interpret is None:
        interpret = _interpret_default()
    indices = jnp.asarray(indices)
    validate_embed_args(table, indices)
    return _embed_agg_jit(table, indices, weights, interpret)


def _pow2_pad_table(page_table):
    """Pad a page table to the next pow2 length (with page id 0) so the
    scan kernel compiles one program per size bucket; padded iterations
    fall past ``n_rows`` and are skipped by the kernel."""
    pps = page_table.shape[0]
    target = 1 << max(pps - 1, 0).bit_length()
    if target == pps:
        return page_table
    return jnp.pad(page_table, (0, target - pps))


@functools.partial(jax.jit, static_argnames=("filter_col", "filter_op",
                                             "interpret"))
def _scan_reduce_jit(pages, page_table, n_rows, threshold, scales,
                     filter_col, filter_op, interpret):
    # the double-buffered kernel DMAs exactly the extent's valid pages
    # out of the (HBM-resident) pool — no interpret-mode compaction
    # gather is needed anymore, and padded table entries cost nothing
    return _scan_reduce(pages, page_table, n_rows, threshold,
                        scales=scales, filter_col=filter_col,
                        filter_op=filter_op, interpret=interpret)


def scan_filter_reduce(pages, page_table, n_rows, threshold=0.0, *,
                       scales=None, filter_col: int = 0,
                       filter_op: str = "all",
                       interpret: bool | None = None):
    """In-storage filtered aggregate over extent pages (jitted,
    double-buffered page pipeline, with the page table padded to a pow2
    bucket to bound recompiles).

    pages: [n_phys, page_rows, n_cols]; page_table: [pps] int32;
    n_rows/threshold: python scalars or [1] arrays; scales: optional
    [n_phys, page_rows] f32 per-row scales of a quantized pool (the
    kernel dequantizes in VMEM — see ``kernels.isp_scan``).
    Returns [8, n_cols] f32 — see ``kernels.isp_scan`` for the layout."""
    if interpret is None:
        interpret = _interpret_default()
    pt = _pow2_pad_table(jnp.asarray(page_table, jnp.int32).reshape(-1))
    nr = jnp.asarray(n_rows, jnp.int32).reshape(1)
    th = jnp.asarray(threshold, jnp.float32).reshape(1)
    return _scan_reduce_jit(pages, pt, nr, th, scales, filter_col,
                            filter_op, interpret)


@functools.partial(jax.jit, static_argnames=("page_rows", "filter_col",
                                             "filter_op"))
def scan_filter_reduce_host(data, threshold=0.0, *, page_rows: int,
                            filter_col: int = 0, filter_op: str = "all"):
    """The host-side reference path (host reads everything, then folds
    page-sequentially) — bit-identical to the in-storage kernel."""
    return ref.scan_filter_reduce_ref(data, page_rows, threshold,
                                      filter_col=filter_col,
                                      filter_op=filter_op)


@functools.partial(jax.jit, static_argnames=("k", "metric", "interpret"))
def _topk_jit(pages, page_table, n_rows, query, scales, k, metric,
              interpret):
    return _topk_scan(pages, page_table, n_rows, query, k=k, metric=metric,
                      scales=scales, interpret=interpret)


def topk_scan(pages, page_table, n_rows, query, *, k: int,
              metric: str = "dot", scales=None,
              interpret: bool | None = None):
    """In-storage query-scored top-k over extent pages (jitted, same
    double-buffered page pipeline and pow2 table bucketing as
    ``scan_filter_reduce``).

    query: [n_cols] (or [1, n_cols]) — zero-pad to the store width.
    Returns [8, topk_pad(k)] f32: scores row 0 (descending), f32 row
    ids row 1; empty slots hold (-1e30, 2^30).  Only this block ever
    crosses the wire — the retrieval wire-reduction story."""
    if interpret is None:
        interpret = _interpret_default()
    n_cols = pages.shape[2]
    pt = _pow2_pad_table(jnp.asarray(page_table, jnp.int32).reshape(-1))
    nr = jnp.asarray(n_rows, jnp.int32).reshape(1)
    q = jnp.asarray(query, jnp.float32).reshape(1, n_cols)
    return _topk_jit(pages, pt, nr, q, scales, k, metric, interpret)


@functools.partial(jax.jit, static_argnames=("page_rows", "k", "metric"))
def topk_scan_host(data, query, *, page_rows: int, k: int,
                   metric: str = "dot"):
    """The host-side retrieval baseline (host fetches the whole extent,
    then folds page-sequentially) — bit-identical to ``topk_scan``."""
    return ref.topk_scan_ref(data, query, page_rows=page_rows, k=k,
                             metric=metric)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _embed_gather_jit(table, indices, interpret: bool):
    return _embed_gather(table, indices, interpret=interpret)


def embed_gather(table, indices, interpret: bool | None = None):
    """Validating wrapper over the batched [B, K] row gather (eager
    bounds check like ``embed_agg``, then one jit per shape bucket)."""
    if interpret is None:
        interpret = _interpret_default()
    indices = jnp.asarray(indices)
    validate_embed_args(table, indices)
    return _embed_gather_jit(table, indices, interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan(r, k, v, logw, u, s0, chunk: int = 32,
              interpret: bool | None = None):
    if interpret is None:
        interpret = _interpret_default()
    return _rwkv(r, k, v, logw, u, s0, chunk=chunk, interpret=interpret)
