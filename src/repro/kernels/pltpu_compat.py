"""Version tolerance for the Pallas TPU API surface.

``pltpu.TPUCompilerParams`` was renamed to ``pltpu.CompilerParams`` in
newer jax releases; kernels import the name from here so they lower on
both sides of the rename.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    pltpu.TPUCompilerParams
