"""RWKV6 chunked wkv recurrence — Pallas TPU kernel.

Grid: (B*H, n_chunks); the chunk axis is sequential so the [dk, dv]
recurrent state persists in VMEM scratch across chunks.  All exponents
are differences of cumulative log-decays (<= 0), f32-safe.  The chunk
math matches ``repro.models.rwkv6.wkv_chunk`` and is validated against
the per-token oracle in ``ref.wkv_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams


def _wkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sT_ref,
                state_ref, *, chunk: int, n_chunks: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # [C, dk]
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # [C, dv]
    logw = w_ref[0].astype(jnp.float32)       # [C, dk]
    u = u_ref[0].astype(jnp.float32)          # [dk]
    s0 = state_ref[...]

    cum = jnp.cumsum(logw, axis=0)
    cum_excl = cum - logw
    diff = cum_excl[:, None, :] - cum[None, :, :]          # [t, s, dk]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = rows > cols
    dmat = jnp.exp(jnp.where(tri[:, :, None], diff, -jnp.inf))
    scores = jnp.einsum("ti,si,tsi->ts", r, k, dmat)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)
    o = scores @ v + diag[:, None] * v
    o = o + (r * jnp.exp(cum_excl)) @ s0
    o_ref[0] = o.astype(o_ref.dtype)

    k2 = k * jnp.exp(cum[-1][None, :] - cum)
    state_ref[...] = jnp.exp(cum[-1])[:, None] * s0 + k2.T @ v

    @pl.when(ci == n_chunks - 1)
    def _finish():
        sT_ref[0] = state_ref[...].astype(sT_ref.dtype)


def rwkv_scan(r, k, v, logw, u, s0, *, chunk: int = 32,
              interpret: bool = False):
    """r/k/logw: [B,S,H,dk]; v: [B,S,H,dv]; u: [H,dk]; s0: [B,H,dk,dv].
    Returns (o [B,S,H,dv], sT [B,H,dk,dv])."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    bh = b * h

    def flat(x):  # [B,S,H,*] -> [B*H, S, *]
        return jnp.moveaxis(x, 2, 1).reshape(bh, s, -1)

    rf, kf, vf, wf = flat(r), flat(k), flat(v), flat(logw)
    s0f = s0.reshape(bh, dk, dv)

    kernel = functools.partial(_wkv_kernel, chunk=chunk, n_chunks=nc)
    o, sT = pl.pallas_call(
        kernel,
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, chunk, dk), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, dk), lambda i, c: (i % h, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, c: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, dv), lambda i, c: (i, c, 0)),
            pl.BlockSpec((1, dk, dv), lambda i, c: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, dv), r.dtype),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dk, dv), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="rwkv_scan",
    )(rf, kf, vf, wf, u, s0f)
    o = jnp.moveaxis(o.reshape(b, h, s, dv), 1, 2)
    return o, sT.reshape(b, h, dk, dv)
