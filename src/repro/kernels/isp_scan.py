"""Paged scan/filter/reduce — Pallas TPU kernel (in-storage analytics).

The paper's I/O-intensive ISP workloads (pattern find/line/word,
rocksdb read, mariadb TPC-H filters) share one shape: stream the
flash-resident data page by page, apply a row predicate, and fold the
survivors into a tiny aggregate — only the aggregate crosses the wire.
TPU adaptation: analytics extents live as stacked pages
``[n_pages, page_rows, n_cols]`` in HBM ("flash",
core.extent_store.ExtentStore); a per-extent page table arrives via
scalar prefetch and the kernel streams exactly the extent's pages
HBM->VMEM — compute moves to the data, the data never moves to the
host.

**Double-buffered pipeline** (the paper's Virtual-FW prefetch): the
pages stay in HBM (``memory_space=ANY``) and the kernel drives its own
async page copies into a two-slot VMEM buffer — while page *i* is being
reduced, the DMA for page *i+1* is already in flight, so data movement
overlaps compute instead of serializing in front of it.  The loop runs
only over the extent's *valid* pages (``ceil(n_rows / page_rows)``);
pow2 table padding costs neither DMA nor compute.

The aggregate layout (``REDUCE_ROWS`` x n_cols, float32):

  row 0  count of rows passing the filter (broadcast across columns)
  row 1  per-column sum over passing rows
  row 2  per-column min over passing rows (+inf when none pass)
  row 3  per-column max over passing rows (-inf when none pass)
  4..7   zero padding (keeps the output tile-aligned on TPU)

Accumulation is page-sequential in float32 — the pipeline changes only
*when bytes move*, never the fold order or its ops, so ``kernels.ref.
scan_filter_reduce_ref`` (which folds pages in the identical order)
remains bit-identical to the in-storage path (the acceptance contract
for offload correctness).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

NEG_INF = -1e30
POS_INF = 1e30

#: filter predicates over the (static) filter column vs the threshold
FILTER_OPS = ("all", "ge", "lt", "eq", "ne")
#: rows of the aggregate output block (see layout above)
REDUCE_ROWS = 8
#: VMEM page slots of the DMA pipeline (2 = classic double buffering)
N_BUFFERS = 2

#: scoring metrics of the top-k scored scan
TOPK_METRICS = ("dot", "cosine")
#: sentinel id of an empty top-k slot (exactly representable in f32,
#: larger than any real row id — the deterministic tie-break loser)
BIG_ID = float(2 ** 30)
#: widest supported k (the merge loop is O(k) per page; the result
#: block must stay tiny — that is the whole wire-reduction story)
MAX_TOPK = 128


def topk_pad(k: int) -> int:
    """Output width of the top-k block: pow2-bucketed, floored at the
    f32 TPU lane tile (128) so the block stays tile-aligned."""
    return max(128, 1 << max(int(k) - 1, 0).bit_length())


def _predicate(key, threshold, op: str):
    if op == "all":
        return jnp.ones_like(key, dtype=jnp.bool_)
    if op == "ge":
        return key >= threshold
    if op == "lt":
        return key < threshold
    if op == "eq":
        return key == threshold
    if op == "ne":
        return key != threshold
    raise ValueError(f"filter_op must be one of {FILTER_OPS}, got {op!r}")


def _init_acc(cnt_ref, sum_ref, min_ref, max_ref):
    cnt_ref[...] = jnp.zeros_like(cnt_ref)
    sum_ref[...] = jnp.zeros_like(sum_ref)
    min_ref[...] = jnp.full_like(min_ref, POS_INF)
    max_ref[...] = jnp.full_like(max_ref, NEG_INF)


def _fold_block(block, pi, n_rows, thresh, cnt_ref, sum_ref, min_ref,
                max_ref, *, page_rows: int, filter_col: int,
                filter_op: str):
    """One page's f32 fold — identical ops and order on the fp and the
    dequantizing pipelines (the bit-identity contract lives here)."""
    pos = pi * page_rows + jax.lax.broadcasted_iota(
        jnp.int32, (page_rows, 1), 0)
    key = block[:, filter_col:filter_col + 1]             # [page_rows, 1]
    mask = (pos < n_rows) & _predicate(key, thresh, filter_op)
    cnt_ref[0, 0] += jnp.sum(mask.astype(jnp.float32))
    sum_ref[0, :] += jnp.sum(jnp.where(mask, block, 0.0), axis=0)
    min_ref[0, :] = jnp.minimum(
        min_ref[0, :], jnp.min(jnp.where(mask, block, POS_INF), axis=0))
    max_ref[0, :] = jnp.maximum(
        max_ref[0, :], jnp.max(jnp.where(mask, block, NEG_INF), axis=0))


def _finish(o_ref, cnt_ref, sum_ref, min_ref, max_ref):
    o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[0, :] = jnp.broadcast_to(cnt_ref[0, 0], o_ref[0, :].shape)
    o_ref[1, :] = sum_ref[0, :]
    o_ref[2, :] = min_ref[0, :]
    o_ref[3, :] = max_ref[0, :]


def _scan_kernel(pt_ref, nrows_ref, thresh_ref, pages_ref, o_ref,
                 buf_ref, sem_ref, cnt_ref, sum_ref, min_ref, max_ref, *,
                 page_rows: int, n_pages: int, filter_col: int,
                 filter_op: str):
    n_rows = nrows_ref[0]
    # valid pages are a prefix of the (pow2-padded) page table; padded
    # entries are never copied nor folded
    n_valid = jnp.minimum(jnp.maximum((n_rows + page_rows - 1) // page_rows,
                                      1), n_pages)

    _init_acc(cnt_ref, sum_ref, min_ref, max_ref)

    def page_dma(slot, idx):
        # one flash page HBM -> VMEM slot, physical id from the
        # scalar-prefetched page table
        return pltpu.make_async_copy(pages_ref.at[pt_ref[idx]],
                                     buf_ref.at[slot], sem_ref.at[slot])

    # prime the pipeline: page 0's copy starts before any compute
    page_dma(0, 0).start()

    def body(pi, _):
        slot = lax.rem(pi, N_BUFFERS)
        nxt = lax.rem(pi + 1, N_BUFFERS)

        # Virtual-FW prefetch: next page's DMA departs while this page
        # is still being reduced
        @pl.when(pi + 1 < n_valid)
        def _prefetch():
            page_dma(nxt, pi + 1).start()

        page_dma(slot, pi).wait()
        block = buf_ref[slot].astype(jnp.float32)         # [page_rows, C]
        _fold_block(block, pi, n_rows, thresh_ref[0], cnt_ref, sum_ref,
                    min_ref, max_ref, page_rows=page_rows,
                    filter_col=filter_col, filter_op=filter_op)
        return ()

    lax.fori_loop(0, n_valid, body, ())
    _finish(o_ref, cnt_ref, sum_ref, min_ref, max_ref)


def _scan_q_kernel(pt_ref, nrows_ref, thresh_ref, pages_ref, scales_ref,
                   o_ref, buf_ref, sbuf_ref, sem_ref, ssem_ref, cnt_ref,
                   sum_ref, min_ref, max_ref, *, page_rows: int,
                   n_pages: int, filter_col: int, filter_op: str):
    """Dequantizing variant: quantized code pages ride the same
    double-buffered DMA pipeline and their per-row scale pages ride a
    second, much smaller one (1/n_cols the bytes).  Dequant happens in
    VMEM right after the copies land — an elementwise f32 multiply, so
    the fold below sees exactly the values the host baseline folds and
    stays bit-identical, while HBM traffic is the quantized bytes."""
    n_rows = nrows_ref[0]
    n_valid = jnp.minimum(jnp.maximum((n_rows + page_rows - 1) // page_rows,
                                      1), n_pages)

    _init_acc(cnt_ref, sum_ref, min_ref, max_ref)

    def page_dma(slot, idx):
        return pltpu.make_async_copy(pages_ref.at[pt_ref[idx]],
                                     buf_ref.at[slot], sem_ref.at[slot])

    def scale_dma(slot, idx):
        return pltpu.make_async_copy(scales_ref.at[pt_ref[idx]],
                                     sbuf_ref.at[slot], ssem_ref.at[slot])

    page_dma(0, 0).start()
    scale_dma(0, 0).start()

    def body(pi, _):
        slot = lax.rem(pi, N_BUFFERS)
        nxt = lax.rem(pi + 1, N_BUFFERS)

        @pl.when(pi + 1 < n_valid)
        def _prefetch():
            page_dma(nxt, pi + 1).start()
            scale_dma(nxt, pi + 1).start()

        page_dma(slot, pi).wait()
        scale_dma(slot, pi).wait()
        # in-VMEM dequant: [page_rows, C] codes x [page_rows, 1] scales
        block = buf_ref[slot].astype(jnp.float32) * sbuf_ref[slot]
        _fold_block(block, pi, n_rows, thresh_ref[0], cnt_ref, sum_ref,
                    min_ref, max_ref, page_rows=page_rows,
                    filter_col=filter_col, filter_op=filter_op)
        return ()

    lax.fori_loop(0, n_valid, body, ())
    _finish(o_ref, cnt_ref, sum_ref, min_ref, max_ref)


def scan_filter_reduce(pages, page_table, n_rows, threshold, *,
                       scales=None, filter_col: int = 0,
                       filter_op: str = "all", interpret: bool = False):
    """Filtered aggregate over an extent's flash-resident pages.

    pages: [n_phys, page_rows, n_cols] (the whole ExtentStore pool —
    it stays in HBM; the kernel DMAs one page at a time);
    page_table: [pps] int32 physical page ids of this extent (pow2-pad
    with any valid id — padded pages past ``n_rows`` cost nothing);
    n_rows: [1] int32 valid rows; threshold: [1] f32 filter operand.
    ``scales`` ([n_phys, page_rows, 1] f32) marks a quantized pool:
    pages hold codes and the kernel dequantizes each page in VMEM right
    after its DMA lands (bit-identical fold, quantized HBM traffic).
    ``filter_col``/``filter_op`` are static (see FILTER_OPS).
    Returns [REDUCE_ROWS, n_cols] float32 (layout in the module doc).
    """
    if filter_op not in FILTER_OPS:
        raise ValueError(f"filter_op must be one of {FILTER_OPS}, "
                         f"got {filter_op!r}")
    n_phys, page_rows, n_cols = pages.shape
    if not 0 <= filter_col < n_cols:
        raise ValueError(f"filter_col {filter_col} out of range "
                         f"[0, {n_cols})")
    pps = page_table.shape[0]

    scratch = [
        pltpu.VMEM((N_BUFFERS, page_rows, n_cols), pages.dtype),
        pltpu.SemaphoreType.DMA((N_BUFFERS,)),
        pltpu.VMEM((1, 1), jnp.float32),          # count
        pltpu.VMEM((1, n_cols), jnp.float32),     # sum
        pltpu.VMEM((1, n_cols), jnp.float32),     # min
        pltpu.VMEM((1, n_cols), jnp.float32),     # max
    ]
    # the page pool (and scale pool) stays in HBM; the kernel's own DMA
    # pipeline pulls pages into the double buffer
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [pages]
    if scales is None:
        kernel = functools.partial(_scan_kernel, page_rows=page_rows,
                                   n_pages=pps, filter_col=filter_col,
                                   filter_op=filter_op)
        name = "scan_filter_reduce"
    else:
        kernel = functools.partial(_scan_q_kernel, page_rows=page_rows,
                                   n_pages=pps, filter_col=filter_col,
                                   filter_op=filter_op)
        name = "scan_filter_reduce_q"
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(scales.reshape(n_phys, page_rows, 1)
                        .astype(jnp.float32))
        # scale double buffer + its own DMA semaphores, spliced right
        # after the code buffer's pair
        scratch[1:1] = [pltpu.VMEM((N_BUFFERS, page_rows, 1), jnp.float32)]
        scratch[3:3] = [pltpu.SemaphoreType.DMA((N_BUFFERS,))]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(1,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((REDUCE_ROWS, n_cols),
                               lambda pi, pt, nr, th: (0, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((REDUCE_ROWS, n_cols), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=name,
    )(page_table, n_rows, threshold, *operands)


# ---------------------------------------------------------------------------
# Scored scan / top-k — the retrieval reducer
#
# Same pipeline, different fold: each page's rows are scored against a
# query vector (dot or cosine) and merged into a k-slot (score, id)
# accumulator.  The output block is [REDUCE_ROWS, topk_pad(k)] f32:
#
#   row 0  top-k scores, descending
#   row 1  matching row ids, stored as f32 (exact for ids < 2^24);
#          empty slots hold (NEG_INF, BIG_ID)
#   2..7   zero padding (tile alignment)
#
# Ties are deterministic: equal scores rank by *smallest row id*, in
# both the kernel and the reference fold — the merge itself is one
# shared function (`_topk_fold_page`), so bit-identity holds by
# construction, not by parallel maintenance.
# ---------------------------------------------------------------------------


def _topk_merge(acc_s, acc_i, cand_s, cand_i, *, k: int):
    """Merge page candidates into the k-slot accumulator.

    All inputs are [1, n] f32 rows.  k selection passes: take the max
    remaining score, break ties on the smallest id, knock the winner
    out, repeat.  Empty slots carry (NEG_INF, BIG_ID) so they lose
    every comparison and tie-break deterministically last.
    """
    c_s = jnp.concatenate([acc_s, cand_s], axis=1)
    c_i = jnp.concatenate([acc_i, cand_i], axis=1)

    def extract(j, carry):
        c_s, c_i, o_s, o_i = carry
        m = jnp.max(c_s)
        cid = jnp.min(jnp.where(c_s == m, c_i, BIG_ID))
        o_s = o_s.at[0, j].set(m)
        o_i = o_i.at[0, j].set(cid)
        # knock the winner out entirely (score AND id) so exhausted
        # slots keep yielding the (NEG_INF, BIG_ID) empty sentinel
        hit = (c_s == m) & (c_i == cid)
        c_s = jnp.where(hit, NEG_INF, c_s)
        c_i = jnp.where(hit, BIG_ID, c_i)
        return c_s, c_i, o_s, o_i

    init = (c_s, c_i,
            jnp.full((1, k), NEG_INF, jnp.float32),
            jnp.full((1, k), BIG_ID, jnp.float32))
    _, _, o_s, o_i = lax.fori_loop(0, k, extract, init)
    return o_s, o_i


def _topk_fold_page(block, pi, n_rows, q, acc_s, acc_i, *,
                    page_rows: int, k: int, metric: str):
    """Score one page's rows and merge them into the accumulator —
    the shared fold of the kernel and ``kernels.ref.topk_scan_ref``
    (identical ops and order: the bit-identity contract lives here).

    Row dots are an explicitly unrolled add chain over the (static)
    column count — NOT ``jnp.sum``/``dot_general``, whose accumulation
    order is implementation-defined and comes out different per
    compilation context (kernel vs jitted host fold: last-ulp score
    divergence breaks the ordering).  Explicit f32 adds have a defined
    order XLA may not reassociate, so both compilations produce the
    same bits.  ``cosine`` divides by the row norm only; ranking is
    invariant to the query's scale, so pre-normalize the query for
    true cosine.
    """
    pos = pi * page_rows + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_rows), 1)

    def rowsum(w):                      # [page_rows, n_cols] -> [1, rows]
        s = w[:, 0]
        for c in range(1, w.shape[1]):
            s = s + w[:, c]
        return s[None, :]

    s = rowsum(block * q)                                 # [1, page_rows]
    if metric == "cosine":
        norm = jnp.sqrt(rowsum(block * block))
        s = s / jnp.maximum(norm, 1e-6)
    valid = pos < n_rows
    s = jnp.where(valid, s, NEG_INF)
    ids = jnp.where(valid, pos.astype(jnp.float32), BIG_ID)
    return _topk_merge(acc_s, acc_i, s, ids, k=k)


def _topk_finish(o_ref, acc_s_ref, acc_i_ref, *, k: int):
    o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[0, :k] = acc_s_ref[0, :]
    o_ref[1, :k] = acc_i_ref[0, :]


def _topk_kernel(pt_ref, nrows_ref, pages_ref, q_ref, o_ref, buf_ref,
                 sem_ref, acc_s_ref, acc_i_ref, *, page_rows: int,
                 n_pages: int, k: int, metric: str):
    n_rows = nrows_ref[0]
    n_valid = jnp.minimum(jnp.maximum((n_rows + page_rows - 1) // page_rows,
                                      1), n_pages)

    acc_s_ref[...] = jnp.full_like(acc_s_ref, NEG_INF)
    acc_i_ref[...] = jnp.full_like(acc_i_ref, BIG_ID)
    q = q_ref[...]                                        # [1, n_cols]

    def page_dma(slot, idx):
        return pltpu.make_async_copy(pages_ref.at[pt_ref[idx]],
                                     buf_ref.at[slot], sem_ref.at[slot])

    page_dma(0, 0).start()

    def body(pi, _):
        slot = lax.rem(pi, N_BUFFERS)
        nxt = lax.rem(pi + 1, N_BUFFERS)

        @pl.when(pi + 1 < n_valid)
        def _prefetch():
            page_dma(nxt, pi + 1).start()

        page_dma(slot, pi).wait()
        block = buf_ref[slot].astype(jnp.float32)         # [page_rows, C]
        acc_s, acc_i = _topk_fold_page(
            block, pi, n_rows, q, acc_s_ref[...], acc_i_ref[...],
            page_rows=page_rows, k=k, metric=metric)
        acc_s_ref[...] = acc_s
        acc_i_ref[...] = acc_i
        return ()

    lax.fori_loop(0, n_valid, body, ())
    _topk_finish(o_ref, acc_s_ref, acc_i_ref, k=k)


def _topk_q_kernel(pt_ref, nrows_ref, pages_ref, scales_ref, q_ref, o_ref,
                   buf_ref, sbuf_ref, sem_ref, ssem_ref, acc_s_ref,
                   acc_i_ref, *, page_rows: int, n_pages: int, k: int,
                   metric: str):
    """Dequantizing top-k: code pages and their per-row scale pages ride
    the same two DMA lanes as ``_scan_q_kernel``; the fold sees exactly
    the f32 values the host baseline folds."""
    n_rows = nrows_ref[0]
    n_valid = jnp.minimum(jnp.maximum((n_rows + page_rows - 1) // page_rows,
                                      1), n_pages)

    acc_s_ref[...] = jnp.full_like(acc_s_ref, NEG_INF)
    acc_i_ref[...] = jnp.full_like(acc_i_ref, BIG_ID)
    q = q_ref[...]

    def page_dma(slot, idx):
        return pltpu.make_async_copy(pages_ref.at[pt_ref[idx]],
                                     buf_ref.at[slot], sem_ref.at[slot])

    def scale_dma(slot, idx):
        return pltpu.make_async_copy(scales_ref.at[pt_ref[idx]],
                                     sbuf_ref.at[slot], ssem_ref.at[slot])

    page_dma(0, 0).start()
    scale_dma(0, 0).start()

    def body(pi, _):
        slot = lax.rem(pi, N_BUFFERS)
        nxt = lax.rem(pi + 1, N_BUFFERS)

        @pl.when(pi + 1 < n_valid)
        def _prefetch():
            page_dma(nxt, pi + 1).start()
            scale_dma(nxt, pi + 1).start()

        page_dma(slot, pi).wait()
        scale_dma(slot, pi).wait()
        block = buf_ref[slot].astype(jnp.float32) * sbuf_ref[slot]
        acc_s, acc_i = _topk_fold_page(
            block, pi, n_rows, q, acc_s_ref[...], acc_i_ref[...],
            page_rows=page_rows, k=k, metric=metric)
        acc_s_ref[...] = acc_s
        acc_i_ref[...] = acc_i
        return ()

    lax.fori_loop(0, n_valid, body, ())
    _topk_finish(o_ref, acc_s_ref, acc_i_ref, k=k)


def topk_scan(pages, page_table, n_rows, query, *, k: int,
              metric: str = "dot", scales=None, interpret: bool = False):
    """Query-scored top-k over an extent's flash-resident pages.

    Same operand contract as :func:`scan_filter_reduce` for ``pages`` /
    ``page_table`` / ``n_rows`` / ``scales``; ``query`` is [1, n_cols]
    f32 (zero-pad to the store width).  ``k`` and ``metric`` (see
    TOPK_METRICS) are static.  Returns [REDUCE_ROWS, topk_pad(k)] f32
    with scores in row 0 and f32 row ids in row 1 — only this tiny
    block ever leaves the device/node.
    """
    if metric not in TOPK_METRICS:
        raise ValueError(f"metric must be one of {TOPK_METRICS}, "
                         f"got {metric!r}")
    if not 1 <= k <= MAX_TOPK:
        raise ValueError(f"k must be in [1, {MAX_TOPK}], got {k}")
    n_phys, page_rows, n_cols = pages.shape
    if query.shape != (1, n_cols):
        raise ValueError(f"query must be [1, {n_cols}], "
                         f"got {query.shape}")
    pps = page_table.shape[0]
    kpad = topk_pad(k)

    scratch = [
        pltpu.VMEM((N_BUFFERS, page_rows, n_cols), pages.dtype),
        pltpu.SemaphoreType.DMA((N_BUFFERS,)),
        pltpu.VMEM((1, k), jnp.float32),      # top-k scores
        pltpu.VMEM((1, k), jnp.float32),      # top-k row ids (as f32)
    ]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY)]
    operands = [pages]
    if scales is None:
        kernel = functools.partial(_topk_kernel, page_rows=page_rows,
                                   n_pages=pps, k=k, metric=metric)
        name = "topk_scan"
    else:
        kernel = functools.partial(_topk_q_kernel, page_rows=page_rows,
                                   n_pages=pps, k=k, metric=metric)
        name = "topk_scan_q"
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))
        operands.append(scales.reshape(n_phys, page_rows, 1)
                        .astype(jnp.float32))
        scratch[1:1] = [pltpu.VMEM((N_BUFFERS, page_rows, 1), jnp.float32)]
        scratch[3:3] = [pltpu.SemaphoreType.DMA((N_BUFFERS,))]
    # the query rides as a plain VMEM block, after the HBM page pools
    in_specs.append(pl.BlockSpec((1, n_cols), lambda pi, pt, nr: (0, 0)))
    operands.append(query.astype(jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(1,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((REDUCE_ROWS, kpad),
                               lambda pi, pt, nr: (0, 0)),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((REDUCE_ROWS, kpad), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
        name=name,
    )(page_table, n_rows, *operands)
