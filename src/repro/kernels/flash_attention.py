"""Blockwise causal flash attention (prefill) — Pallas TPU kernel.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks); the k-block axis is
the innermost (sequential) dimension so the online-softmax accumulators
live in VMEM scratch across k steps.  GQA is handled in the k/v index
maps (kv_head = q_head // group).  Block shapes are MXU-aligned
(multiples of 128 on the lane dim, block_q x head_dim tiles in VMEM).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  causal: bool, block_q: int, block_k: int, sm_scale: float,
                  num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    run = True
    if causal:
        # skip k blocks strictly above the diagonal
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0, :, :].astype(jnp.float32)           # [bq, d]
        k = k_ref[0, 0, :, :].astype(jnp.float32)           # [bk, d]
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * sm_scale                                  # [bq, bk]
        if causal:
            rows = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            cols = ki * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False):
    """q: [B, H, Sq, D]; k/v: [B, Hkv, Sk, D] -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    sm_scale = 1.0 / math.sqrt(d)

    grid = (b, h, nq, nk)
    kernel = functools.partial(_flash_kernel, causal=causal, block_q=block_q,
                               block_k=block_k, sm_scale=sm_scale,
                               num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, ki: (bb, hh // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bb, hh, qi, ki: (bb, hh // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qi, ki: (bb, hh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
