"""Embedding-bag aggregation — Pallas TPU kernel (DLRM 'embed' workload).

The paper's rm1/rm2 workloads do sparse embedding-table lookups +
sum-pooling inside the SSD.  TPU adaptation: the table shard lives in
HBM ("flash"); lookup indices arrive via scalar prefetch so each grid
step DMAs exactly one table row HBM->VMEM and accumulates the pool in
the output block — a near-data gather that never materializes [B, L, D].

Grid: (batch, lookups); the lookup axis is sequential per sample.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pltpu_compat import CompilerParams


def _embed_kernel(idx_ref, w_ref, table_ref, o_ref, *, weighted: bool):
    b = pl.program_id(0)
    li = pl.program_id(1)

    @pl.when(li == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    row = table_ref[0, :].astype(jnp.float32)
    if weighted:
        row = row * w_ref[b, li]
    o_ref[0, :] = o_ref[0, :] + row.astype(o_ref.dtype)


def validate_embed_args(table, indices):
    """Reject malformed lookups before they reach ``pallas_call``.

    The BlockSpec index map would silently clamp an out-of-range vocab
    id to the last table row — a wrong answer, not an error — so bounds
    are checked here whenever the indices are concrete (eager callers;
    tracers inside an enclosing jit skip the value check but still get
    the dtype/shape checks).
    """
    if indices.ndim != 2:
        raise ValueError(f"indices must be [B, L], got shape "
                         f"{tuple(indices.shape)}")
    if not jnp.issubdtype(indices.dtype, jnp.integer):
        raise TypeError(f"indices must be an integer dtype (int32), got "
                        f"{indices.dtype}")
    v = table.shape[0]
    if not isinstance(indices, jax.core.Tracer):
        # one fused reduction -> one host transfer (not two syncs)
        lo, hi = map(int, jax.device_get(
            jnp.stack([jnp.min(indices), jnp.max(indices)])))
        if lo < 0 or hi >= v:
            raise ValueError(
                f"embedding indices out of range: min={lo} max={hi} but "
                f"vocab size is {v} (valid ids are [0, {v - 1}])")


def embed_agg(table, indices, weights=None, *, interpret: bool = False):
    """table: [V, D]; indices: [B, L] int32; weights: optional [B, L] f32.
    Returns [B, D] sum-pooled embeddings."""
    validate_embed_args(table, indices)
    v, d = table.shape
    b, l = indices.shape
    weighted = weights is not None
    if weights is None:
        weights = jnp.ones((b, l), jnp.float32)

    kernel = functools.partial(_embed_kernel, weighted=weighted)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, l),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # weights, whole array
            pl.BlockSpec((1, d), lambda bb, li, idx: (idx[bb, li], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bb, li, idx: (bb, 0)),
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="embed_agg",
    )(indices, weights, table)


def _gather_kernel(idx_ref, table_ref, o_ref):
    o_ref[0, :] = table_ref[0, :]


def embed_gather(table, indices, *, interpret: bool = False):
    """Batched multi-query row gather (the retrieval-assembly side).

    table: [V, D]; indices: [B, K] int32 — e.g. one top-k id table per
    query.  Returns [B, K, D] = table[indices], gathered in ONE kernel
    launch (one jit per [B, K] bucket) so retrieval assembly never
    loops host-side per request.  Rows keep the table's dtype (token
    blocks stay int32).
    """
    validate_embed_args(table, indices)
    v, d = table.shape
    b, kk = indices.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, kk),
        in_specs=[
            pl.BlockSpec((1, d), lambda bb, li, idx: (idx[bb, li], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda bb, li, idx: (bb * kk + li, 0)),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * kk, d), table.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="embed_gather",
    )(indices, table)
    return out.reshape(b, kk, d)
